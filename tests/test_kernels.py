"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps.

Every kernel is validated against ref.py; the chunked refs are additionally
validated against the naive materialized-scores oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention
from repro.kernels.entropy_features import byte_entropy
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_pack import quant_pack
from repro.kernels.ssd_scan import ssd_scan


def _qkv(key, B, Sq, Sk, Hq, Hkv, D, dtype, Dv=None):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, Sq, Hq, D), dtype)
    k = jax.random.normal(k2, (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(k3, (B, Sk, Hkv, Dv or D), dtype)
    return q, k, v


# ------------------------------------------------------------- chunked refs
@pytest.mark.parametrize("Sq,Sk,window", [(32, 32, None), (64, 64, 16),
                                          (16, 48, None)])
def test_flash_ref_matches_naive(Sq, Sk, window):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, Sq, Sk, 4, 2, 16, jnp.float32)
    out_ref = R.flash_attention_ref(q, k, v, causal=True, window=window,
                                    chunk=16)
    out_naive = R.attention_naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_naive),
                               rtol=2e-5, atol=2e-5)


def test_decode_ref_matches_naive():
    key = jax.random.PRNGKey(1)
    B, S, Hq, Hkv, D = 3, 40, 8, 2, 16
    q = jax.random.normal(key, (B, Hq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    kv_len = jnp.array([5, 17, 40])
    out = R.decode_attention_ref(q, k, v, kv_len, chunk=16)
    for b in range(B):
        L = int(kv_len[b])
        ref = R.attention_naive(q[b:b + 1, None], k[b:b + 1, :L],
                                v[b:b + 1, :L], causal=False)
        np.testing.assert_allclose(np.asarray(out[b]),
                                   np.asarray(ref[0, 0]), rtol=2e-5, atol=2e-5)


# -------------------------------------------------------- flash kernel sweep
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,D,window,softcap", [
    (1, 128, 4, 4, 64, None, None),      # MHA
    (2, 96, 8, 2, 32, None, None),       # GQA, non-multiple seq
    (1, 256, 4, 1, 64, 64, None),        # MQA + sliding window
    (1, 128, 2, 2, 64, None, 50.0),      # logit softcap (gemma2)
])
def test_flash_kernel_vs_ref(B, S, Hq, Hkv, D, window, softcap, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, S, Hq, Hkv, D, dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          softcap=softcap, block_q=64, block_k=64,
                          interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=True, window=window,
                                softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_kernel_noncausal_and_dv():
    """Cross-attention shape: non-causal, Dv != Dk (MLA-style)."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 64, 64, 4, 2, 48,
                   jnp.float32, Dv=32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                          interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------- decode kernel sweep
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,D,window", [
    (2, 256, 8, 2, 64, None),
    (1, 512, 4, 1, 128, None),           # MQA long cache
    (3, 200, 8, 8, 32, 64),              # MHA + window, ragged lengths
])
def test_decode_kernel_vs_ref(B, S, Hq, Hkv, D, window, dtype):
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (B, Hq, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D), dtype)
    kv_len = jnp.asarray(np.random.default_rng(0).integers(window or 2, S + 1,
                                                           B))
    out = decode_attention(q, k, v, kv_len, window=window, block_k=64,
                           interpret=True)
    ref = R.decode_attention_ref(q, k, v, kv_len, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


# --------------------------------------------------------------- SSD kernel
def _ssd_inputs(key, b, s, h, p, g, n, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n), dtype) * 0.5
    C = jax.random.normal(jax.random.fold_in(key, 9), (b, s, g, n), dtype) * 0.5
    D = jnp.ones((h,))
    return x, dt, A, B, C, D


def test_ssd_ref_matches_sequential():
    """Chunked SSD ref == naive per-step recurrence."""
    x, dt, A, B, C, D = _ssd_inputs(jax.random.PRNGKey(5), 1, 24, 2, 4, 1, 8)
    y_ref, st_ref = R.ssd_scan_ref(x, dt, A, B, C, D, chunk=8)
    # sequential oracle
    state = jnp.zeros((1, 2, 4, 8))
    ys = []
    for t in range(24):
        y_t, state = R.ssd_step_ref(state, x[:, t], dt[:, t], A, B[:, t],
                                    C[:, t], D)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_ref), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 64, 2, 8, 1, 16, 16),
    (2, 48, 4, 16, 2, 8, 16),     # grouped B/C, non-multiple seq
    (1, 100, 3, 8, 1, 8, 32),     # ragged tail chunk
])
def test_ssd_kernel_vs_ref(b, s, h, p, g, n, chunk):
    x, dt, A, B, C, D = _ssd_inputs(jax.random.PRNGKey(6), b, s, h, p, g, n)
    y, st = ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    y_ref, st_ref = R.ssd_scan_ref(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.reshape(st_ref.shape)),
                               np.asarray(st_ref), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ entropy kernel
@pytest.mark.parametrize("n,block", [(1000, 256), (8192, 1024), (37, 64)])
def test_entropy_kernel_vs_ref(n, block):
    data = jnp.asarray(np.random.default_rng(0).integers(0, 256, n), jnp.uint8)
    hist, ent = byte_entropy(data, block=block, interpret=True)
    hist_ref, ent_ref = R.byte_entropy_ref(data)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(hist_ref))
    np.testing.assert_allclose(float(ent), float(ent_ref), rtol=1e-5)


def test_entropy_matches_numpy_oracle():
    data = np.random.default_rng(1).integers(0, 16, 4096).astype(np.uint8)
    _, ent = byte_entropy(jnp.asarray(data), interpret=True)
    counts = np.bincount(data, minlength=256)
    p = counts / counts.sum()
    ent_np = -(p[p > 0] * np.log2(p[p > 0])).sum()
    assert abs(float(ent) - ent_np) < 1e-4


def _entropy_numpy(data: np.ndarray):
    """Histogram/entropy golden reference (bits per byte)."""
    hist = np.bincount(data, minlength=256)
    p = hist / max(len(data), 1)
    nz = p[p > 0]
    return hist, float(-(nz * np.log2(nz)).sum())


@pytest.mark.parametrize("n,block", [
    (4096, 1024),     # n % block == 0: empty-pad block boundary
    (4097, 1024),     # one byte spills into a heavily padded final block
    (5000, 1024),     # n not divisible by block
    (100, 1024),      # n < block: block clamps to n, no pad
    (1, 64),          # single byte
])
def test_entropy_golden_vs_numpy(n, block):
    """interpret=True kernel vs the NumPy histogram/entropy reference; pad
    bytes (zeros) must never leak into the histogram."""
    data = np.random.default_rng(n).integers(1, 256, n).astype(np.uint8)
    hist, ent = byte_entropy(jnp.asarray(data), block=block, interpret=True)
    hist_np, ent_np = _entropy_numpy(data)
    np.testing.assert_array_equal(np.asarray(hist), hist_np)
    assert int(np.asarray(hist)[0]) == 0, "zero-pad leaked into histogram"
    assert float(ent) == pytest.approx(ent_np, abs=1e-4)


def test_entropy_all_identical_bytes_is_zero():
    """A constant payload carries 0 bits/byte, exactly."""
    data = np.full(3000, 7, np.uint8)
    hist, ent = byte_entropy(jnp.asarray(data), block=512, interpret=True)
    assert float(ent) == 0.0
    assert int(np.asarray(hist)[7]) == 3000 and int(np.asarray(hist).sum()) == 3000


@pytest.mark.parametrize("n_symbols,expect_bits", [(2, 1.0), (4, 2.0),
                                                   (256, 8.0)])
def test_entropy_uniform_alphabet_golden(n_symbols, expect_bits):
    """Uniform k-symbol alphabets have exactly log2(k) bits/byte."""
    data = np.tile(np.arange(n_symbols, dtype=np.uint8), 16)
    _, ent = byte_entropy(jnp.asarray(data), block=128, interpret=True)
    assert float(ent) == pytest.approx(expect_bits, abs=1e-5)


# -------------------------------------------------------------- quant kernel
@pytest.mark.parametrize("shape", [(4, 256), (1024,), (3, 2, 512)])
def test_quant_kernel_vs_ref(shape):
    x = jax.random.normal(jax.random.PRNGKey(7), shape) * 5.0
    q, s = quant_pack(x, interpret=True)
    q_ref, s_ref = R.quant_pack_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)
    back = ops.quant_unpack(q, s)
    assert float(jnp.abs(back - x).max()) <= float(jnp.abs(x).max()) / 127.0 + 1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_quant_roundtrip_property(seed):
    """|dequant(quant(x)) - x| <= blockmax/127 for every block."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 256)) * \
        (1.0 + (seed % 7))
    q, s = R.quant_pack_ref(x)
    back = R.quant_unpack_ref(q, s)
    err = jnp.abs(back - x).max(axis=1)
    bound = jnp.abs(x).max(axis=1) / 127.0 * 0.5 + 1e-7
    assert bool((err <= bound + 1e-6).all())


# ----------------------------------------------------------- ops dispatcher
def test_ops_dispatch_ref_on_cpu():
    q, k, v = _qkv(jax.random.PRNGKey(8), 1, 32, 32, 2, 2, 16, jnp.float32)
    a = ops.flash_attention(q, k, v)          # auto -> ref on CPU
    b = ops.flash_attention(q, k, v, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
