"""Property-style billing parity: for EVERY selected subset of candidate
moves, the cents a ``TieredStore`` meters while executing the partial plan
equal the ``MigrationPlan``'s own per-move cents arrays — across re-encode,
cross-provider egress, and early-delete composition, batch and streaming.

This is the contract the daemon's budget accounting (and the async
migrator's attempted-spend ledger) stands on: ``select(keep)`` must revert
deferred moves *exactly*, never just approximately.
"""

import numpy as np
import pytest

from repro.core.costs import (CostTable, ProviderCostTable, azure_table,
                              multi_cloud_table)
from repro.core.engine import (CompressStage, PartitionedData,
                               PlacementEngine, ScopeConfig, StreamingEngine)
from repro.storage.store import TieredStore

_DET = ("read_cents", "write_cents", "penalty_cents", "egress_cents")


def _alpha_beta():
    """Two providers with opposite storage/read trade-offs (mirrors the
    multicloud test fixture): drift forces provider moves that pay egress;
    beta:cold carries a 1-month minimum stay for early-delete coverage."""
    alpha = CostTable(
        storage_cents_gb_month=np.array([10.0, 8.0]),
        read_cents_gb=np.array([0.1, 0.5]),
        write_cents_gb=np.array([0.05, 0.05]),
        ttfb_seconds=np.array([0.01, 0.05]),
        capacity_gb=np.array([np.inf, np.inf]),
        early_delete_months=np.array([0.0, 0.0]),
        names=("hot", "warm"))
    beta = CostTable(
        storage_cents_gb_month=np.array([2.0, 0.2]),
        read_cents_gb=np.array([1.0, 4.0]),
        write_cents_gb=np.array([0.05, 0.05]),
        ttfb_seconds=np.array([0.05, 0.2]),
        capacity_gb=np.array([np.inf, np.inf]),
        early_delete_months=np.array([0.0, 1.0]),
        names=("std", "cold"))
    return multi_cloud_table([ProviderCostTable("alpha", alpha, 5.0, np.inf),
                              ProviderCostTable("beta", beta, 7.0, np.inf)])


def _payload_plan(table, tier_whitelist):
    raws = [(bytes([65 + i % 8]) * (150_000 + 40_000 * i)) for i in range(8)]
    cfg = ScopeConfig(tier_whitelist=tier_whitelist, months=2.0)
    eng = PlacementEngine(table, cfg)
    data = PartitionedData(
        partitions=[None] * len(raws), tables=[None] * len(raws),
        raw_bytes=raws, spans_gb=np.array([len(b) / 1e9 for b in raws]),
        rho=np.array([0.05, 0.1, 40.0, 0.02, 800.0, 5.0, 0.5, 120.0]))
    return eng, eng.solve(CompressStage(cfg)(data, table))


def _assert_store_meters_exactly(table, tier_whitelist, months_held,
                                 seed, n_masks=8):
    eng, plan = _payload_plan(table, tier_whitelist)
    rng = np.random.default_rng(seed)
    rho2 = plan.problem.rho * rng.uniform(1e-4, 1e4, plan.problem.n)
    full = eng.reoptimize(plan, rho2, months_held=months_held)
    assert full.n_candidates >= 2
    masks = [np.zeros(plan.problem.n, bool), np.ones(plan.problem.n, bool)]
    masks += [rng.random(plan.problem.n) < 0.5 for _ in range(n_masks)]
    for keep in masks:
        sub = full.select(keep)
        store = TieredStore(table)
        keys = store.apply_plan(plan)
        store.advance_months(months_held)
        before = {f: getattr(store.meter, f) for f in _DET}
        store.migrate(sub, keys)
        d = {f: getattr(store.meter, f) - before[f] for f in _DET}
        transfer = float(np.where(sub.moved, sub.move_transfer_cents,
                                  0.0).sum())
        assert d["read_cents"] + d["write_cents"] == \
            pytest.approx(transfer, rel=1e-9, abs=1e-15)
        assert d["egress_cents"] == pytest.approx(
            sub.egress_cents, rel=1e-9, abs=1e-15)
        assert d["penalty_cents"] == pytest.approx(
            sub.penalty_cents, rel=1e-9, abs=1e-15)
        assert sum(d.values()) == pytest.approx(
            sub.total_move_cents, rel=1e-9, abs=1e-15)


def test_batch_subsets_meter_exactly_with_reencode_and_early_delete():
    # azure archive tier: 6-month min stay, so months_held=2 composes
    # early-delete penalties with lz4/zlib re-encodes
    _assert_store_meters_exactly(azure_table(), (0, 1, 2, 3),
                                 months_held=2.0, seed=0)


@pytest.mark.parametrize("seed", [1, 2])
def test_batch_subsets_meter_exactly_cross_provider(seed):
    # alpha<->beta moves pay the source provider's egress exactly once;
    # months_held=0.5 keeps beta:cold inside its 1-month minimum stay
    _assert_store_meters_exactly(_alpha_beta(), (0, 1, 2, 3),
                                 months_held=0.5, seed=seed)


def test_stream_subsets_meter_exactly():
    """Random keep masks through the streaming select hook: each step the
    store-metered move cents equal the selected plan's cents exactly
    (fixed partition set — infinite window, no compaction — so sync_plan
    performs moves only after the first batch)."""
    table = _alpha_beta()
    cfg = ScopeConfig(use_compression=False, months=1.0)
    # file sizes (GB) must equal the actual payload bytes the store bills,
    # or plan cents and meter cents diverge by construction
    fbytes = {f"d{i}/{j}": 200_000 + 60_000 * j
              for i in range(3) for j in range(3)}
    sizes = {f: b / 1e9 for f, b in fbytes.items()}
    eng = StreamingEngine(table, cfg, sizes, s_thresh=5.0, window=1,
                          drift_threshold=np.inf)
    store = TieredStore(table)
    rng = np.random.default_rng(7)
    fams = [("d0/0", "d0/1"), ("d1/0", "d1/1"), ("d2/0", "d2/1")]
    payload = {f: b"s" * sum(fbytes[x] for x in f) for f in fams}
    for step in range(6):
        # every family flips hot<->cold each batch: candidates every step
        rates = [500.0 if (step + i) % 2 == 0 else 0.01
                 for i in range(len(fams))]
        batch = [(f, float(r)) for f, r in zip(fams, rates)]
        mask = rng.random(len(fams)) < 0.5

        def select(mig):
            return mask[:mig.moved.shape[0]]

        mig = eng.ingest_and_reoptimize(batch, months=1.0,
                                        select_moves=select)
        store.advance_months(1.0)
        before = {f: getattr(store.meter, f) for f in _DET}
        parts = mig.plan.problem.partitions
        stats = store.sync_plan(
            mig.plan, payloads=[payload[tuple(sorted(p.files))]
                                for p in parts])
        d = {f: getattr(store.meter, f) - before[f] for f in _DET}
        if step == 0:
            assert stats["put"] == len(fams)
            continue
        assert stats["put"] == 0 and stats["deleted"] == 0
        assert d["egress_cents"] == pytest.approx(
            mig.egress_cents, rel=1e-9, abs=1e-15)
        assert d["penalty_cents"] == pytest.approx(
            mig.penalty_cents, rel=1e-9, abs=1e-15)
        assert sum(d.values()) == pytest.approx(
            mig.total_move_cents, rel=1e-9, abs=1e-15)
    moves = sum(r.n_moved for r in eng.history)
    deferred = sum(r.n_deferred for r in eng.history)
    assert moves > 0 and deferred > 0     # masks actually bit both ways


def test_sla_penalties_never_leak_into_store_meter():
    """With a serving SLA configured (lambda > 0, finite target) the solve
    may pick different placements — but every cent the store meters must
    still equal the plan's own pure-money move cents, and the meter's
    total must stay exactly the sum of its cents fields (no latency units
    hiding anywhere in BillingMeter)."""
    import dataclasses

    table = azure_table()
    raws = [(bytes([65 + i % 8]) * (150_000 + 40_000 * i)) for i in range(8)]
    cfg = ScopeConfig(tier_whitelist=(0, 1, 2, 3), months=2.0,
                      sla_lambda=3.0, sla_ms=30.0)
    eng = PlacementEngine(table, cfg)
    data = PartitionedData(
        partitions=[None] * len(raws), tables=[None] * len(raws),
        raw_bytes=raws, spans_gb=np.array([len(b) / 1e9 for b in raws]),
        rho=np.array([0.05, 0.1, 40.0, 0.02, 800.0, 5.0, 0.5, 120.0]))
    plan = eng.solve(CompressStage(cfg)(data, table))
    assert plan.report.sla_penalty >= 0.0
    rng = np.random.default_rng(3)
    rho2 = plan.problem.rho * rng.uniform(1e-4, 1e4, plan.problem.n)
    full = eng.reoptimize(plan, rho2, months_held=2.0)
    for keep in [np.ones(plan.problem.n, bool),
                 rng.random(plan.problem.n) < 0.5]:
        sub = full.select(keep)
        store = TieredStore(table)
        keys = store.apply_plan(plan)
        store.advance_months(2.0)
        before = {f: getattr(store.meter, f) for f in _DET}
        store.migrate(sub, keys)
        d = {f: getattr(store.meter, f) - before[f] for f in _DET}
        assert sum(d.values()) == pytest.approx(
            sub.total_move_cents, rel=1e-9, abs=1e-15)
        # the meter's grand total is the sum of its cents fields — a
        # latency penalty folded in anywhere would break this identity
        m = store.meter
        assert m.total_cents == pytest.approx(
            m.storage_cents + m.read_cents + m.write_cents + m.compute_cents
            + m.penalty_cents + m.egress_cents, rel=1e-12)
    # penalty units live only in the report, and never in the billed cents:
    # billing the same assignment with lambda=0 yields identical cents
    cfg0 = dataclasses.replace(cfg, sla_lambda=0.0)
    from repro.core.engine import BillingStage
    rep0 = BillingStage(table, cfg0)(
        dataclasses.replace(plan.problem, cfg=cfg0), plan.assignment)
    for f in ("storage_cents", "decomp_cents", "read_cents", "total_cents"):
        assert getattr(rep0, f) == getattr(plan.report, f), f
