"""Checkpoint manager (SCOPe-tiered, async, crash-safe) + data loader
(prefetch, stragglers, deterministic ownership)."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.loader import (TieredDataLoader, shard_owner,
                               write_token_shards)
from repro.storage.store import TieredStore


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    return {"w": jax.random.normal(k1, (128, 64)) * scale,
            "stages": (jax.random.normal(k2, (2, 32, 32)),),
            "step": jnp.asarray(3, jnp.int32)}


def test_save_restore_roundtrip():
    store = TieredStore()
    mgr = CheckpointManager(store)
    tree = _tree(0)
    mgr.save(100, tree, blocking=True)
    out, step = mgr.restore(tree)
    assert step == 100
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest():
    store = TieredStore()
    mgr = CheckpointManager(store)
    tree = _tree(1)
    mgr.save(1, tree)
    mgr.save(2, tree)          # waits for 1, then async-writes 2
    mgr.wait()
    assert mgr.latest_step() == 2


def test_crash_mid_save_falls_back():
    """Blobs without a manifest are invisible to restore (manifest-last)."""
    store = TieredStore()
    mgr = CheckpointManager(store)
    tree = _tree(2)
    mgr.save(10, tree, blocking=True)
    # simulate a crash: shard blobs of step 20 written, manifest missing
    store.put("ckpt/20/00000", b"garbage", tier=0)
    mgr2 = CheckpointManager(store)          # fresh process after restart
    out, step = mgr2.restore(tree)
    assert step == 10


def test_lifecycle_migrates_old_checkpoints_cooler():
    store = TieredStore()
    mgr = CheckpointManager(store, keep=10)
    tree = _tree(3)
    for s in range(5):
        mgr.save(s, tree, blocking=True)
    # oldest checkpoints should sit in cooler tiers than the newest
    man_old = mgr._manifests[0]["shards"]
    man_new = mgr._manifests[4]["shards"]
    mean_old = np.mean([store.tier_of(m["key"]) for m in man_old])
    mean_new = np.mean([store.tier_of(m["key"]) for m in man_new])
    assert mean_old >= mean_new
    # every byte still restorable after migrations
    out, step = mgr.restore(tree, step=0)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoints_are_compressed():
    store = TieredStore()
    mgr = CheckpointManager(store)
    tree = {"w": jnp.zeros((1024, 256))}     # highly compressible
    mgr.save(0, tree, blocking=True)
    stored = sum(store.stored_gb(k) for k in store.keys()
                 if not k.endswith("MANIFEST"))
    raw = 1024 * 256 * 4 / 1e9
    assert stored < raw / 10                 # codec chosen, big win


def test_retention_deletes_old():
    store = TieredStore()
    mgr = CheckpointManager(store, keep=2)
    tree = {"w": jnp.ones((64,))}
    for s in range(5):
        mgr.save(s, tree, blocking=True)
    assert sorted(mgr._manifests) == [3, 4]


# ------------------------------------------------------------------- loader
def test_loader_batches_and_determinism():
    store = TieredStore()
    shards = write_token_shards(store, n_shards=6, rows=8, seq=16, vocab=100)
    dl = TieredDataLoader(store, shards, batch=4, seq=16)
    batches = list(dl.batches(epoch=0))
    assert batches and batches[0]["tokens"].shape == (4, 16)
    assert (batches[0]["labels"][:, :-1] == batches[0]["tokens"][:, 1:]).all()
    dl2 = TieredDataLoader(store, shards, batch=4, seq=16)
    batches2 = list(dl2.batches(epoch=0))
    np.testing.assert_array_equal(batches[0]["tokens"], batches2[0]["tokens"])


def test_loader_ownership_partition():
    store = TieredStore()
    shards = write_token_shards(store, n_shards=20, rows=2, seq=8, vocab=50)
    loaders = [TieredDataLoader(store, shards, batch=2, seq=8,
                                host_id=h, n_hosts=4) for h in range(4)]
    owned = [set(l.my_shards(0)) for l in loaders]
    assert set().union(*owned) == set(shards)          # full coverage
    for i in range(4):
        for j in range(i + 1, 4):
            assert not owned[i] & owned[j]             # disjoint


def test_loader_straggler_speculative_retry():
    store = TieredStore()
    shards = write_token_shards(store, n_shards=4, rows=8, seq=8, vocab=50)
    slow_once = {"armed": True}

    def flaky_fetch(key, replica):
        if replica == 0 and key.endswith("00002") and slow_once["armed"]:
            time.sleep(1.0)                            # primary straggles
        return store.get(key)

    dl = TieredDataLoader(store, shards, batch=4, seq=8,
                          fetch_fn=flaky_fetch, straggler_factor=2.0,
                          fetch_timeout_s=5.0)
    # warm the EWMA with fast fetches, then hit the straggler
    for k in [s for s in shards if not s.endswith("00002")]:
        dl.fetch_with_backup(k)
    t0 = time.perf_counter()
    blob = dl.fetch_with_backup("data/00002")
    dt = time.perf_counter() - t0
    assert dl.stats.speculative_retries == 1
    assert dt < 0.9                                    # beat the 1s straggler
    assert len(blob) > 0
