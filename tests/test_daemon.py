"""ReoptimizationDaemon: budget-capped selection, deferral bookkeeping,
parity with plain reoptimize/ingest_and_reoptimize, knapsack correctness.
"""

import numpy as np
import pytest

from repro.core.costs import azure_table
from repro.core.daemon import (DaemonCycleReport, MigrationBudget,
                               ReoptimizationDaemon, linear_trend_forecast)
from repro.core.engine import (PlacementEngine, PlacementProblem, ScopeConfig,
                               StreamingEngine)
from repro.core.optassign import budgeted_moves
from repro.storage.store import TieredStore


# ------------------------------------------------------------ knapsack unit
def test_budgeted_moves_cap_binds_exactly():
    """Constructed instance where the greedy fill lands exactly on the cap."""
    savings = np.array([10.0, 8.0, 6.0, 1.0])
    cents = np.array([3.0, 3.0, 4.0, 0.0])
    for method in ("greedy", "exact"):
        keep = budgeted_moves(savings, cents, 6.0, method=method)
        assert keep.tolist() == [True, True, False, True], method
        assert cents[keep].sum() == 6.0, method  # binds exactly


def test_budgeted_moves_infinite_budget_selects_all_candidates():
    cand = np.array([True, False, True])
    keep = budgeted_moves(np.array([1.0, 5.0, -2.0]), np.array([9., 9., 9.]),
                          np.inf, candidates=cand)
    assert (keep == cand).all()


def test_budgeted_moves_gb_cap_and_zero_cost():
    savings = np.array([5.0, 4.0, 3.0])
    cents = np.array([0.0, 1.0, 1.0])
    gb = np.array([10.0, 6.0, 5.0])
    keep = budgeted_moves(savings, cents, np.inf, move_gb=gb, budget_gb=16.0,
                          method="greedy")
    # zero-cost best-ratio move first (10 GB), then only the 6 GB one fits
    assert keep.tolist() == [True, True, False]
    keep = budgeted_moves(savings, cents, 0.0, move_gb=gb, budget_gb=np.inf,
                          method="greedy")
    assert keep.tolist() == [True, False, False]  # only free moves fit


def test_budgeted_moves_greedy_matches_exact_on_tiny_instances():
    """Equal-cost instances: ratio order == savings order, so greedy is
    optimal and must match the exact enumeration; on general instances the
    exact oracle is never worse."""
    rng = np.random.default_rng(0)
    for trial in range(25):
        n = int(rng.integers(1, 9))
        s = rng.uniform(0.5, 10.0, n)
        # equal costs: greedy == exact
        c = np.full(n, 2.0)
        budget = float(rng.integers(0, 2 * n + 1))
        g = budgeted_moves(s, c, budget, method="greedy")
        e = budgeted_moves(s, c, budget, method="exact")
        assert s[g].sum() == pytest.approx(s[e].sum()), trial
        # general costs: exact >= greedy, both within budget
        c = rng.uniform(0.5, 4.0, n)
        g = budgeted_moves(s, c, budget, method="greedy")
        e = budgeted_moves(s, c, budget, method="exact")
        assert c[g].sum() <= budget + 1e-9 and c[e].sum() <= budget + 1e-9
        assert s[e].sum() >= s[g].sum() - 1e-9, trial


def test_budgeted_moves_negative_savings_rank_last_on_both_paths():
    """A negative-projected-savings candidate (e.g. a capacity-forced move
    the solver insists on) is still taken when budget remains — on BOTH the
    greedy and the exact path — but never displaces positive savings."""
    savings = np.array([5.0, -2.0])
    cents = np.array([3.0, 3.0])
    for method in ("greedy", "exact"):
        # room for both: take both (selection schedules, doesn't judge)
        assert budgeted_moves(savings, cents, 6.0,
                              method=method).tolist() == [True, True]
        # room for one: the positive-savings move wins
        assert budgeted_moves(savings, cents, 3.0,
                              method=method).tolist() == [True, False]


def test_budgeted_moves_priority_aging_promotes_old_moves():
    """A deferred move's aging boost eventually outranks a fresher,
    higher-ratio competitor."""
    savings = np.array([10.0, 6.0])
    cents = np.array([5.0, 5.0])          # budget fits exactly one
    keep = budgeted_moves(savings, cents, 5.0, method="greedy")
    assert keep.tolist() == [True, False]
    aged = budgeted_moves(savings, cents, 5.0, method="greedy",
                          priority=np.array([1.0, 2.0]))
    assert aged.tolist() == [False, True]


# ------------------------------------------------------------- batch fixture
def _batch_setup(N=40, seed=0):
    table = azure_table()
    cfg = ScopeConfig(tier_whitelist=(0, 1, 2, 3), schemes=("none", "lz4"))
    rng = np.random.default_rng(seed)
    spans = rng.lognormal(0.0, 1.2, N) * 2.0
    rho = rng.gamma(0.7, 25.0, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, 1))], 1)
    D = np.concatenate([np.zeros((N, 1)),
                        rng.uniform(0.01, 2.0, (N, 1)) * spans[:, None]], 1)
    prob = PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(N, -1), R=R, D=D,
                            schemes=cfg.schemes, table=table, cfg=cfg)
    eng = PlacementEngine(table, cfg)
    plan0 = eng.solve(prob)
    drifts = []
    r = rho.copy()
    for t in range(4):
        r = r.copy()
        r[3 * t:3 * t + 3] *= 50.0
        drifts.append(r.copy())
    return eng, plan0, drifts


def test_batch_daemon_infinite_budget_is_bit_identical_to_reoptimize():
    """Acceptance: infinite budget + zero rho_abs_tol reproduces the plain
    reoptimize chain exactly — plans and metered cents bit-identical."""
    eng, plan0, drifts = _batch_setup()
    N = plan0.problem.n
    cur, held, manual = plan0, np.zeros(N), []
    for r in drifts:
        h = held + 1.0
        mig = eng.reoptimize(cur, r, months_held=h)
        held = np.where(mig.moved, 0.0, h)
        cur = mig.plan
        manual.append(mig)

    d = ReoptimizationDaemon(eng, plan=plan0)
    reps = d.run(drifts, months=1.0)
    for mig, rep in zip(manual, reps):
        assert rep.n_selected == mig.n_moved and rep.n_deferred == 0
        assert rep.spent_cents == mig.total_move_cents          # exact
        assert rep.egress_cents == mig.egress_cents
        assert rep.steady_cents == mig.plan.report.total_cents
    assert np.array_equal(d.plan.assignment.tier, cur.assignment.tier)
    assert np.array_equal(d.plan.assignment.scheme, cur.assignment.scheme)
    assert d.plan.report.total_cents == cur.report.total_cents


def test_batch_daemon_budget_cap_never_exceeded_and_charge_once():
    """Per-cycle spent_cents <= cap always; once drift stops, deferred moves
    drain and later cycles charge nothing (charge-once across deferrals)."""
    eng, plan0, drifts = _batch_setup()
    # pad with quiet cycles so every deferred move has budget to drain into
    cycles = drifts + [drifts[-1]] * 8
    unb = ReoptimizationDaemon(eng, plan=plan0)
    unb.run(cycles, months=1.0)
    # the cap must admit the single most expensive move or it can never
    # drain; 1.2x the per-move max still forces multi-move cycles to split
    cur, held, per_move = plan0, np.zeros(plan0.problem.n), [0.0]
    for r in cycles:
        h = held + 1.0
        mig = eng.reoptimize(cur, r, months_held=h)
        held = np.where(mig.moved, 0.0, h)
        cur = mig.plan
        per_move.append(float((mig.move_transfer_cents + mig.move_egress_cents
                               + mig.move_penalty_cents).max()))
    cap = 1.2 * max(per_move)
    assert cap < max(r.spent_cents for r in unb.history)  # cap actually binds
    d = ReoptimizationDaemon(eng, plan=plan0,
                             budget=MigrationBudget(cents_per_cycle=cap))
    reps = d.run(cycles, months=1.0)
    for rep in reps:
        assert rep.spent_cents <= cap + 1e-9
        assert (rep.migration_cents + rep.egress_cents + rep.penalty_cents
                == pytest.approx(rep.spent_cents))
    # the queue drains: no pending deferral at the end, and the last quiet
    # cycles are free (nothing re-charged for moves already executed)
    assert reps[-1].n_deferred == 0
    assert reps[-1].spent_cents == 0.0 and reps[-2].spent_cents == 0.0
    # converges to the same steady placement as the unbudgeted daemon
    assert np.array_equal(d.plan.assignment.tier, unb.plan.assignment.tier)
    assert d.plan.report.total_cents == pytest.approx(
        unb.plan.report.total_cents)


def test_batch_daemon_deferred_moves_age_and_execute_later():
    eng, plan0, drifts = _batch_setup()
    cycles = [drifts[0]] * 6
    unb = ReoptimizationDaemon(eng, plan=plan0)
    rep0 = unb.step(drifts[0], months=1.0)
    assert rep0.n_selected >= 2, "fixture needs >= 2 moves on first drift"
    # admits any single move but not the whole first cycle: some must wait
    mig0 = eng.reoptimize(plan0, drifts[0], months_held=1.0)
    cap = 1.2 * float((mig0.move_transfer_cents + mig0.move_egress_cents
                       + mig0.move_penalty_cents).max())
    assert cap < rep0.spent_cents
    d = ReoptimizationDaemon(eng, plan=plan0,
                             budget=MigrationBudget(cents_per_cycle=cap))
    reps = d.run(cycles, months=1.0)
    assert any(r.n_deferred > 0 for r in reps)
    assert any(r.max_deferral_age >= 1 for r in reps)
    # every proposed move eventually executes
    assert sum(r.n_selected for r in reps) == rep0.n_selected
    assert reps[-1].n_deferred == 0


def test_min_stay_deferral_postpones_penalized_moves():
    """A move whose early-delete penalty exceeds its projected steady saving
    is postponed under a finite budget even when the budget would allow it;
    as the residency clock prorates the penalty away, it executes."""
    table = azure_table()
    cfg = ScopeConfig(tier_whitelist=(2, 3), schemes=("none",), months=1.0)
    eng = PlacementEngine(table, cfg)
    # one 1 GB partition, placed on Cool (tier 2: 1-month minimum stay)
    prob = PlacementProblem(
        spans_gb=np.array([1.0]), rho=np.array([4.0]),
        current_tier=np.full(1, -1), R=np.ones((1, 1)), D=np.zeros((1, 1)),
        schemes=("none",), table=table, cfg=cfg)
    plan = eng.solve(prob)
    assert plan.assignment.tier[0] == 2
    cold = np.array([0.01])  # went cold: Archive wins on steady storage.
    # At 0.3 months held, the solver proposes the move (the prorated
    # penalty is below the cfg.months saving), but over the daemon's short
    # projection horizon the penalty still dominates -> deferred.
    d = ReoptimizationDaemon(
        eng, plan=plan, budget=MigrationBudget(cents_per_cycle=1e9),
        horizon_months=0.25, rho_rel_tol=0.25)
    rep1 = d.step(cold, months=0.3)
    assert rep1.n_candidates == 1 and rep1.n_selected == 0
    assert rep1.n_deferred == 1 and rep1.penalty_cents == 0.0
    # after the minimum stay elapses the penalty is zero and the move runs
    rep2 = d.step(cold, months=1.0)
    assert rep2.n_selected == 1 and rep2.penalty_cents == 0.0


def _payload_plan():
    """Real-payload plan (truth-measured R/D) so a store can apply it."""
    from repro.core.engine import CompressStage, PartitionedData
    table = azure_table()
    raws = [(bytes([65 + i % 8]) * (200_000 + 50_000 * i)) for i in range(6)]
    cfg = ScopeConfig(tier_whitelist=(0, 1, 2), months=2.0)
    eng = PlacementEngine(table, cfg)
    data = PartitionedData(
        partitions=[None] * len(raws), tables=[None] * len(raws),
        raw_bytes=raws, spans_gb=np.array([len(b) / 1e9 for b in raws]),
        rho=np.array([0.05, 0.1, 40.0, 0.02, 800.0, 5.0]))
    return eng, eng.solve(CompressStage(cfg)(data, table))


def test_batch_daemon_store_integration_meters_exactly():
    """Attached TieredStore bills exactly the selected cents each cycle,
    and its residency clocks agree with the daemon's."""
    eng, plan0 = _payload_plan()
    store = TieredStore(eng.table)
    keys = store.apply_plan(plan0)
    drift = plan0.problem.rho.copy()
    drift[0] *= 5000.0
    drift[4] /= 5000.0
    unb = ReoptimizationDaemon(eng, plan=plan0)
    unb.step(drift, months=1.0)
    assert unb.history[0].n_selected >= 2
    cap = 0.75 * unb.history[0].spent_cents
    d = ReoptimizationDaemon(eng, plan=plan0, store=store, store_keys=keys,
                             budget=MigrationBudget(cents_per_cycle=cap))
    for _ in range(3):
        m0 = store.meter
        r0, w0, p0 = m0.read_cents, m0.write_cents, m0.penalty_cents
        rep = d.step(drift, months=1.0)
        transfer = (store.meter.read_cents - r0) + (store.meter.write_cents
                                                    - w0)
        assert transfer == pytest.approx(
            rep.migration_cents + rep.egress_cents, rel=1e-9, abs=1e-12)
        assert store.meter.penalty_cents - p0 == pytest.approx(
            rep.penalty_cents, rel=1e-9, abs=1e-12)
    np.testing.assert_allclose(store.months_held(keys), d._months_held)


def test_batch_daemon_forecast_hook_feeds_projected_rho():
    eng, plan0, drifts = _batch_setup()
    target = plan0.problem.rho * 3.0

    def forecast(history):
        assert isinstance(history, list) and len(history) >= 1
        return target

    d = ReoptimizationDaemon(eng, plan=plan0, forecast_fn=forecast)
    d.step(plan0.problem.rho.copy(), months=1.0)
    np.testing.assert_array_equal(d.plan.problem.rho, target)


def test_linear_trend_forecast():
    assert linear_trend_forecast([3.0]) == 3.0
    assert linear_trend_forecast([1.0, 2.0, 3.0]) == pytest.approx(4.0)
    # clamps at zero on a downward trend
    assert linear_trend_forecast([2.0, 1.0, 0.2]) == pytest.approx(0.0)
    # vector histories broadcast (batch mode)
    out = linear_trend_forecast([np.array([1.0, 5.0]), np.array([2.0, 3.0])])
    np.testing.assert_allclose(out, [3.0, 1.0])


# ---------------------------------------------------------------- streaming
def _stream_engine(**kw):
    cfg = ScopeConfig(use_compression=False, months=1.0)
    sizes = {f"d{i}/{j}": 0.5 + 0.1 * j for i in range(6) for j in range(4)}
    return StreamingEngine(azure_table(), cfg, sizes, s_thresh=5.0,
                           window=1, drift_threshold=np.inf, **kw)


def _stream_batch(h=400.0, c1=0.01, c2=0.01):
    return [(("d0/0", "d0/1"), h),
            (("d1/0", "d1/1", "d1/2"), c1),
            (("d2/0", "d2/1"), c2)]


def _stream_cycles():
    quiet = _stream_batch()
    hot = _stream_batch(c1=500.0, c2=450.0)
    return [quiet, quiet, hot, hot, hot, hot]


def test_stream_daemon_infinite_budget_is_bit_identical():
    e1 = _stream_engine()
    migs = [e1.ingest_and_reoptimize(b, months=1.0) for b in _stream_cycles()]
    e2 = _stream_engine()
    d = ReoptimizationDaemon(e2)
    reps = d.run(_stream_cycles(), months=1.0)
    for m, r in zip(migs, reps):
        assert r.n_selected == m.n_moved and r.n_deferred == 0
        assert r.spent_cents == m.total_move_cents
        assert r.steady_cents == m.plan.report.total_cents
    assert np.array_equal(e2.plan.assignment.tier, e1.plan.assignment.tier)
    for s1, s2 in zip(e1.history, e2.history):
        assert s1 == s2


def test_stream_daemon_budget_defers_then_converges():
    e1 = _stream_engine()
    migs = [e1.ingest_and_reoptimize(b, months=1.0) for b in _stream_cycles()]
    per_move = max(float((m.move_transfer_cents + m.move_egress_cents
                          + m.move_penalty_cents).max()) for m in migs)
    cap = per_move * 1.001                 # budget fits one move per cycle
    e2 = _stream_engine()
    d = ReoptimizationDaemon(e2, budget=MigrationBudget(cents_per_cycle=cap))
    reps = d.run(_stream_cycles(), months=1.0)
    for r in reps:
        assert r.spent_cents <= cap + 1e-9
    assert any(r.n_deferred > 0 for r in reps)
    assert sum(r.n_selected for r in reps) == sum(m.n_moved for m in migs)
    assert reps[-1].n_deferred == 0 and reps[-1].spent_cents == 0.0
    # same final placement per file set as the unbudgeted stream
    held1 = {k: (s[0].tier, s[0].scheme) for k, s in e1._held.items()}
    held2 = {k: (s[0].tier, s[0].scheme) for k, s in e2._held.items()}
    assert held1 == held2


def test_stream_daemon_rejects_plan_argument():
    with pytest.raises(ValueError):
        ReoptimizationDaemon(_stream_engine(), plan=object())
    with pytest.raises(ValueError):
        ReoptimizationDaemon(PlacementEngine(azure_table(), ScopeConfig()))


def test_stream_daemon_rejects_tolerance_arguments():
    """Hysteresis lives on the StreamingEngine; silently dropping the
    daemon's tolerance args would defeat the floor the caller asked for."""
    with pytest.raises(ValueError):
        ReoptimizationDaemon(_stream_engine(), rho_abs_tol=1.0)
    with pytest.raises(ValueError):
        ReoptimizationDaemon(_stream_engine(), rho_rel_tol=0.5)


def test_batch_daemon_deferred_scheme_change_stays_in_candidate_set():
    """Budget-deferred moves must keep their drift-lock base: without the
    carried rho_ref, the next cycle re-bases rho, sees no drift, re-locks
    the old scheme, and the deferred re-compression silently vanishes."""
    import dataclasses as dc
    from repro.core.engine import PlacementPlan
    table = azure_table()
    cfg = ScopeConfig(tier_whitelist=(1,), schemes=("none", "lz4"),
                      months=2.0)
    eng = PlacementEngine(table, cfg)
    prob = PlacementProblem(
        spans_gb=np.array([1.0]), rho=np.array([10.0]),
        current_tier=np.full(1, -1), R=np.ones((1, 2)), D=np.zeros((1, 2)),
        schemes=("none", "lz4"), table=table, cfg=cfg)
    plan = eng.solve(prob)
    assert plan.assignment.scheme[0] == 0
    # the predictor later learns lz4 gives 5x; rho drifts past the gate
    better = dc.replace(prob, R=np.array([[1.0, 5.0]]))
    plan = PlacementPlan(better, plan.assignment, plan.report)
    hot = np.array([100.0])
    d = ReoptimizationDaemon(eng, plan=plan,
                             budget=MigrationBudget(cents_per_cycle=0.0))
    rep1 = d.step(hot, months=1.0)
    assert rep1.n_candidates == 1 and rep1.n_deferred == 1
    # same rates next cycle: the deferred re-compression is RE-proposed
    rep2 = d.step(hot, months=1.0)
    assert rep2.n_candidates == 1 and rep2.n_deferred == 1
    assert rep2.max_deferral_age == 2
    # budget restored: the move finally executes
    d.budget = MigrationBudget()
    rep3 = d.step(hot, months=1.0)
    assert rep3.n_selected == 1
    assert d.plan.assignment.scheme[0] == 1


def test_daemon_reports_are_dataclasses_with_stable_fields():
    e = _stream_engine()
    d = ReoptimizationDaemon(e)
    rep = d.step(_stream_batch(), months=1.0)
    assert isinstance(rep, DaemonCycleReport)
    assert rep.cycle == 0 and rep.n_partitions > 0
    assert rep.spent_cents == pytest.approx(
        rep.migration_cents + rep.egress_cents + rep.penalty_cents)


# ------------------------------------------------- amortized move-splitting
def test_budgeted_moves_paid_cents_reduces_residual_charge():
    savings = np.array([10.0, 8.0])
    cents = np.array([7.0, 7.0])
    # neither move fits a 4c cap cold...
    keep = budgeted_moves(savings, cents, 4.0)
    assert not keep.any()
    # ...but with 5c prepaid on move 0 its residual (2c) fits
    keep = budgeted_moves(savings, cents, 4.0,
                          paid_cents=np.array([5.0, 0.0]))
    assert keep[0] and not keep[1]
    # over-payment clamps at zero residual, never a negative charge that
    # would free budget for other moves
    keep = budgeted_moves(savings, cents, 4.0,
                          paid_cents=np.array([9.0, 0.0]))
    assert keep[0] and not keep[1]
    # residuals that jointly fit both land
    keep = budgeted_moves(savings, cents, 4.0,
                          paid_cents=np.array([5.0, 5.0]))
    assert keep.all()


def test_batch_daemon_amortizes_oversized_moves_until_they_land():
    """A cap below every single move's charge starves the plain daemon
    forever; with amortize_oversized the daemon banks installments each
    cycle and the moves eventually land. Budget invariant per cycle:
    real spend (spent - prepaid consumed) + installment <= cap."""
    eng, plan0, drifts = _batch_setup()
    mig0 = eng.reoptimize(plan0, drifts[0], months_held=1.0)
    charges = (mig0.move_transfer_cents + mig0.move_egress_cents
               + mig0.move_penalty_cents)[mig0.moved]
    assert charges.size >= 2
    cap = float(charges.max()) / 3.5      # smaller than ANY move's charge
    assert cap < float(charges.min()) or cap < float(charges.max())
    cycles = [drifts[0]] * 12

    plain = ReoptimizationDaemon(eng, plan=plan0,
                                 budget=MigrationBudget(cents_per_cycle=cap))
    plain_reps = plain.run(cycles, months=1.0)
    stuck = [r for r in plain_reps
             if r.n_deferred > 0 and r.n_selected == 0]
    assert len(stuck) >= 2          # oversized moves starve without amortize

    d = ReoptimizationDaemon(eng, plan=plan0, amortize_oversized=True,
                             budget=MigrationBudget(cents_per_cycle=cap))
    reps = d.run(cycles, months=1.0)
    for rep in reps:
        out_of_pocket = rep.spent_cents - rep.prepaid_used_cents
        assert out_of_pocket + rep.installment_cents <= cap + 1e-9
    assert any(r.installment_cents > 0 for r in reps)
    assert any(r.prepaid_used_cents > 0 for r in reps)
    # oversized moves the plain daemon starves forever land here
    assert (sum(r.n_selected for r in reps)
            > sum(r.n_selected for r in plain_reps))
    # nothing left half-paid once the queue drains
    assert reps[-1].n_deferred == 0 or reps[-1].installment_cents > 0


def test_amortize_oversized_rejected_outside_batch_mode():
    e = _stream_engine()
    with pytest.raises(ValueError, match="batch-mode only"):
        ReoptimizationDaemon(e, amortize_oversized=True)


def test_stream_forecast_history_survives_transient_absence():
    """Rolling-window churn drops a partition from one batch and brings it
    back in the next; its forecast calibration must survive. Only
    ``forecast_window`` CONSECUTIVE absences retire the history."""
    from repro.core.stream import occurrence_keys

    class _P:
        def __init__(self, *files):
            self.files = frozenset(files)

    eng = _stream_engine()
    d = ReoptimizationDaemon(eng, forecast_fn=lambda h: float(np.mean(h)),
                             forecast_window=2)
    a, b = _P("d0/0"), _P("d1/0")
    ka = occurrence_keys([a])[0]
    d._project_stream([a, b], np.array([4.0, 7.0]))
    assert ka in d._rho_hist
    # absent one batch: calibration retained, miss counter starts
    d._project_stream([b], np.array([7.0]))
    assert ka in d._rho_hist and d._rho_miss[ka] == 1
    # reappears: forecast still blends the pre-absence observation
    out = d._project_stream([a, b], np.array([6.0, 7.0]))
    assert out[0] == pytest.approx(5.0)           # mean(4.0, 6.0)
    assert ka not in d._rho_miss
    # forecast_window consecutive absences -> history and counter purged
    d._project_stream([b], np.array([7.0]))
    d._project_stream([b], np.array([7.0]))
    assert ka not in d._rho_hist and ka not in d._rho_miss
