"""Latency-SLA objective extension, serving cache tier, and K-replicas.

Pins the PR's hard contract:

* ``sla_lambda=0`` with no cache tier is **byte-identical** to the pre-SLA
  engine across the batch, streaming, and fleet paths (the parity pin).
* SLA latency penalties are *reported*, never billed — ``total_cents``
  stays pure money; cache storage/fill spend IS money.
* Cache admission is forecast-driven, deterministic, and respects the
  capacity; replicas land on distinct providers (or tiers).
"""

import dataclasses

import numpy as np
import pytest

from repro.core.cache import (CacheConfig, ReactiveLRUCache,
                              cache_access_adjustment, cache_cents,
                              forecast_admission, served_latency_terms,
                              weighted_p99_ms)
from repro.core.costs import (Weights, azure_table, big3_table, cost_tensor,
                              latency_feasible, sla_penalty_tensor)
from repro.core.daemon import ReoptimizationDaemon
from repro.core.engine import (AssignStage, BillingStage, PlacementEngine,
                               PlacementProblem, ScopeConfig, StreamingEngine)
from repro.core.fleet import FleetEngine
from repro.core.optassign import capacitated_assign, capacitated_assign_batch


def _problem(rng, N, cfg, table=None, K=3, rho_scale=20.0):
    table = table if table is not None else azure_table()
    spans = rng.uniform(0.5, 50.0, N)
    rho = rng.gamma(1.0, rho_scale, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.2, 6.0, (N, K - 1))],
                       1)
    D = np.concatenate([np.zeros((N, 1)),
                        rng.uniform(0.01, 3.0, (N, K - 1))], 1)
    return PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(N, -1), R=R, D=D,
                            schemes=("none", "lz4", "zstd")[:K],
                            table=table, cfg=cfg)


# ------------------------------------------------------------ penalty algebra
def test_sla_penalty_tensor_hand_values():
    """rho-weighted relu((ttfb + D) * 1e3 - sla); inf SLA rows exactly 0."""
    t = azure_table()                       # ttfb ms: 5.3, 61.4, 61.4, 3.6e6
    rho = np.array([2.0, 0.5])
    D = np.array([[0.0], [0.1]])
    sla = np.array([10.0, np.inf])
    pen = sla_penalty_tensor(rho, sla, D, t)
    assert pen.shape == (2, t.num_tiers, 1)
    assert pen[0, 0, 0] == 0.0              # 5.3 ms < 10 ms target
    assert pen[0, 1, 0] == pytest.approx(2.0 * (61.4 - 10.0))
    assert (pen[1] == 0.0).all()            # inf target -> zero, no NaN
    assert (pen >= 0.0).all()
    # linear in rho
    pen2 = sla_penalty_tensor(3.0 * rho, sla, D, t)
    np.testing.assert_allclose(pen2, 3.0 * pen)


def test_cost_table_retrieval_latency_ms():
    t = azure_table()
    np.testing.assert_allclose(t.retrieval_latency_ms, t.ttfb_seconds * 1e3)


def test_solver_sla_fold_matches_manual_fold_and_zero_is_noop():
    """capacitated_assign(sla_lambda=L) == capacitated_assign(cost + L*P);
    sla_lambda=0 is bit-identical to omitting the penalty entirely."""
    rng = np.random.default_rng(7)
    t = azure_table()
    N, K = 12, 2
    spans = rng.uniform(0.5, 20.0, N)
    rho = rng.gamma(1.0, 30.0, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.5, 5.0, (N, 1))], 1)
    D = np.concatenate([np.zeros((N, 1)), rng.uniform(0.01, 1.0, (N, 1))], 1)
    cost = cost_tensor(spans, rho, np.full(N, -1), R, D, t, Weights(),
                       months=3.0)
    feas = np.ones_like(cost, bool)
    stored = np.repeat((spans[:, None] / R)[:, None, :], t.num_tiers, 1)
    cap = np.array([spans.sum() / 4, spans.sum() / 2, spans.sum(), np.inf])
    pen = sla_penalty_tensor(rho, np.full(N, 20.0), D, t)

    base = capacitated_assign(cost, feas, stored, cap)
    zer = capacitated_assign(cost, feas, stored, cap, sla_penalty=pen,
                             sla_lambda=0.0)
    assert np.array_equal(base.tier, zer.tier)
    assert np.array_equal(base.scheme, zer.scheme)
    assert base.cost == zer.cost

    lam = 0.37
    a = capacitated_assign(cost, feas, stored, cap, sla_penalty=pen,
                           sla_lambda=lam)
    b = capacitated_assign(cost + lam * pen, feas, stored, cap)
    assert np.array_equal(a.tier, b.tier)
    assert np.array_equal(a.scheme, b.scheme)
    assert a.cost == b.cost

    # and through the batched fleet entry point
    fa = capacitated_assign_batch([cost], [feas], [stored], cap,
                                  sla_penalties=[pen], sla_lambda=lam)
    assert np.array_equal(fa.assignments[0].tier, b.tier)
    assert np.array_equal(fa.assignments[0].scheme, b.scheme)


# --------------------------------------------------------------- parity pins
def test_batch_plan_bit_parity_with_lambda_zero():
    """sla_lambda=0 + no cache: plan byte-identical to the default config,
    even with a finite sla_ms configured (the target alone changes nothing
    about the solve)."""
    rng = np.random.default_rng(1)
    t = azure_table()
    base_cfg = ScopeConfig()
    sla_cfg = ScopeConfig(sla_lambda=0.0, sla_ms=50.0)
    p0 = _problem(np.random.default_rng(1), 20, base_cfg, t)
    p1 = _problem(np.random.default_rng(1), 20, sla_cfg, t)
    pl0 = PlacementEngine(t, base_cfg).solve(p0)
    pl1 = PlacementEngine(t, sla_cfg).solve(p1)
    assert pl0.assignment.tier.tobytes() == pl1.assignment.tier.tobytes()
    assert pl0.assignment.scheme.tobytes() == pl1.assignment.scheme.tobytes()
    assert pl0.assignment.cost == pl1.assignment.cost
    for f in ("storage_cents", "decomp_cents", "read_cents", "total_cents"):
        assert getattr(pl0.report, f) == getattr(pl1.report, f), f
    # the target IS visible in the reported (non-billed) penalty metric
    assert pl1.report.sla_penalty > 0.0
    assert pl0.report.sla_penalty == 0.0            # inf default target
    assert pl1.report.cache_cents == 0.0 and pl1.report.n_cached == 0


def test_serving_terms_none_when_inactive():
    """The single fold point returns (None, None) when the features are
    off — the solver input arrays are the very same objects as before."""
    t = azure_table()
    for cfg in (ScopeConfig(), ScopeConfig(sla_lambda=0.0, sla_ms=25.0),
                ScopeConfig(sla_lambda=2.0)):       # lambda>0 but inf target
        prob = _problem(np.random.default_rng(3), 8, cfg, t)
        cached, serving = AssignStage(t, cfg).serving_terms(prob)
        assert cached is None and serving is None, cfg


def _stream_engines(cfg_a, cfg_b):
    sizes = {f"d{i}/{j}": 0.5 + 0.1 * j for i in range(6) for j in range(4)}
    return (StreamingEngine(azure_table(), cfg_a, sizes, s_thresh=5.0,
                            window=1, drift_threshold=np.inf),
            StreamingEngine(azure_table(), cfg_b, sizes, s_thresh=5.0,
                            window=1, drift_threshold=np.inf))


def test_streaming_bit_parity_with_lambda_zero():
    cfg_a = ScopeConfig(use_compression=False, months=1.0)
    cfg_b = dataclasses.replace(cfg_a, sla_lambda=0.0, sla_ms=40.0)
    ea, eb = _stream_engines(cfg_a, cfg_b)
    batches = [
        [(("d0/0", "d0/1"), 400.0), (("d1/0", "d1/1", "d1/2"), 0.01)],
        [(("d0/0", "d0/1"), 400.0), (("d1/0", "d1/1", "d1/2"), 500.0)],
        [(("d0/0", "d0/1"), 2.0), (("d1/0", "d1/1", "d1/2"), 500.0)],
    ]
    for batch in batches:
        ma = ea.ingest_and_reoptimize(batch)
        mb = eb.ingest_and_reoptimize(batch)
        assert ma.plan.assignment.tier.tobytes() \
            == mb.plan.assignment.tier.tobytes()
        assert ma.plan.assignment.scheme.tobytes() \
            == mb.plan.assignment.scheme.tobytes()
        assert ma.migration_cents == mb.migration_cents
        assert ma.penalty_cents == mb.penalty_cents
        assert ma.plan.report.total_cents == mb.plan.report.total_cents


def test_fleet_bit_parity_with_lambda_zero():
    t = azure_table()
    cfg_a = ScopeConfig(capacity_gb=np.array([50.0, 100.0, np.inf, np.inf]))
    cfg_b = dataclasses.replace(cfg_a, sla_lambda=0.0, sla_ms=30.0)
    probs_a = [_problem(np.random.default_rng(s), 9, cfg_a, t)
               for s in (0, 1, 2)]
    probs_b = [_problem(np.random.default_rng(s), 9, cfg_b, t)
               for s in (0, 1, 2)]
    fa = FleetEngine(t, cfg_a).solve(probs_a)
    fb = FleetEngine(t, cfg_b).solve(probs_b)
    assert fa.total_cents == fb.total_cents
    for pa, pb in zip(fa.plans, fb.plans):
        assert pa.assignment.tier.tobytes() == pb.assignment.tier.tobytes()
        assert pa.assignment.scheme.tobytes() == pb.assignment.scheme.tobytes()


# --------------------------------------------------- lambda actually steers
def test_lambda_sweep_trades_cents_for_penalty():
    """On the uncapacitated (exact per-partition argmin) path, raising
    lambda never increases the reported penalty and never decreases the
    billed cents — the Pareto frontier the benchmark sweeps."""
    t = azure_table()
    rng = np.random.default_rng(5)
    prev_pen, prev_cents = np.inf, -np.inf
    hit_distinct = set()
    for lam in (0.0, 0.005, 0.05, 5.0):
        cfg = ScopeConfig(sla_lambda=lam, sla_ms=30.0)
        prob = _problem(np.random.default_rng(5), 24, cfg, t, rho_scale=5.0)
        plan = PlacementEngine(t, cfg).solve(prob)
        pen, cents = plan.report.sla_penalty, plan.report.total_cents
        assert pen <= prev_pen + 1e-9
        assert cents >= prev_cents - 1e-9
        prev_pen, prev_cents = pen, cents
        hit_distinct.add(round(cents, 6))
    assert len(hit_distinct) >= 2           # lambda actually moved the plan
    assert prev_pen < np.inf


def test_penalty_never_billed_as_cents():
    """Same assignment billed under lambda=0 and lambda=5: every cents
    field identical; only the reported penalty metric is nonzero."""
    t = azure_table()
    cfg0 = ScopeConfig(sla_ms=30.0, sla_lambda=0.0)
    cfg5 = dataclasses.replace(cfg0, sla_lambda=5.0)
    prob0 = _problem(np.random.default_rng(9), 15, cfg0, t)
    plan = PlacementEngine(t, cfg0).solve(prob0)
    prob5 = dataclasses.replace(prob0, cfg=cfg5)
    rep5 = BillingStage(t, cfg5)(prob5, plan.assignment)
    for f in ("storage_cents", "decomp_cents", "read_cents", "total_cents",
              "cache_cents"):
        assert getattr(plan.report, f) == getattr(rep5, f), f
    assert rep5.sla_penalty == plan.report.sla_penalty
    assert rep5.total_cents == (rep5.storage_cents + rep5.decomp_cents
                                + rep5.read_cents)


# ------------------------------------------------------------------- cache
def test_forecast_admission_capacity_density_and_gates():
    spans = np.array([1.0, 1.0, 2.0, 10.0])
    rho = np.array([10.0, 5.0, 6.0, 100.0])
    cfg = CacheConfig(capacity_gb=3.0)
    cached = forecast_admission(rho, spans, cfg)
    # idx3 can never fit; density order 0 (10), 1 (5), 2 (3): 0 and 1 fit,
    # then 2 (2 GB) no longer does
    assert cached.tolist() == [True, True, False, False]
    # min_rho floor: rho=5 drops out, rho=6 (2 GB) now fits alongside idx0
    cached = forecast_admission(rho, spans,
                                dataclasses.replace(cfg, min_rho=6.0))
    assert cached.tolist() == [True, False, True, False]
    # p_hot gate
    cached = forecast_admission(rho, spans, cfg,
                                p_hot=np.array([0.9, 0.1, 0.9, 0.9]))
    assert cached.tolist() == [True, False, True, False]
    # deterministic
    again = forecast_admission(rho, spans, cfg)
    assert np.array_equal(again, np.array([True, True, False, False]))


def test_cache_access_adjustment_signs_and_zero_rows():
    t = azure_table()
    rng = np.random.default_rng(2)
    N, K = 6, 2
    rho = rng.gamma(1.0, 20.0, N)
    R = np.concatenate([np.ones((N, 1)), rng.uniform(1.5, 4.0, (N, 1))], 1)
    D = np.concatenate([np.zeros((N, 1)), rng.uniform(0.01, 1.0, (N, 1))], 1)
    spans = rng.uniform(1.0, 10.0, N)
    stored = np.repeat((spans[:, None] / R)[:, None, :], t.num_tiers, 1)
    cached = np.array([True, False, True, False, False, False])
    adj = cache_access_adjustment(rho, stored, D, t, Weights(), cached, 0.05)
    assert (adj[~cached] == 0.0).all()
    assert (adj[cached] <= 0.0).all()       # always relief, never surcharge
    # relief equals (1 - miss) x the access part of the cost tensor
    full = cost_tensor(spans, rho, np.full(N, -1), R, D, t, Weights(),
                       months=3.0)
    none = cost_tensor(spans, np.zeros(N), np.full(N, -1), R, D, t,
                       Weights(), months=3.0)
    np.testing.assert_allclose(adj[cached],
                               -(1.0 - 0.05) * (full - none)[cached],
                               rtol=1e-12)


def test_cache_lowers_p99_and_bills_cache_spend():
    t = azure_table()
    rng = np.random.default_rng(11)
    base_cfg = ScopeConfig(sla_ms=30.0, sla_lambda=0.0)
    cache = CacheConfig(capacity_gb=40.0, hit_latency_ms=1.0, min_rho=5.0)
    cache_cfg = dataclasses.replace(base_cfg, cache=cache)
    prob0 = _problem(np.random.default_rng(11), 18, base_cfg, t)
    plan0 = PlacementEngine(t, base_cfg).solve(prob0)
    prob1 = dataclasses.replace(prob0, cfg=cache_cfg)
    plan1 = PlacementEngine(t, cache_cfg).solve(prob1)
    assert plan1.report.n_cached > 0
    assert plan1.report.cache_cents > 0.0
    assert plan1.report.p99_latency_ms <= plan0.report.p99_latency_ms
    assert plan1.report.sla_penalty < plan0.report.sla_penalty
    # cache spend is real money inside total_cents
    assert plan1.report.total_cents == pytest.approx(
        plan1.report.storage_cents + plan1.report.decomp_cents
        + plan1.report.read_cents + plan1.report.cache_cents)
    # admission mask the report used is the pure function of (rho, spans)
    cached = forecast_admission(prob1.rho, prob1.spans_gb, cache)
    assert plan1.report.n_cached == int(cached.sum())
    assert plan1.report.cache_cents == pytest.approx(
        cache_cents(prob1.spans_gb, cached, cache, base_cfg.months))


def test_weighted_p99_hand_values():
    lat = np.array([1.0, 100.0])
    assert weighted_p99_ms(lat, np.array([99.0, 1.0])) == 1.0
    assert weighted_p99_ms(lat, np.array([98.0, 2.0])) == 100.0
    assert weighted_p99_ms(lat, np.zeros(2)) == 0.0
    assert weighted_p99_ms(np.zeros(0), np.zeros(0)) == 0.0
    # unsorted input
    assert weighted_p99_ms(np.array([100.0, 1.0]),
                           np.array([2.0, 98.0])) == 100.0


def test_served_latency_terms_mass_conservation():
    rho = np.array([4.0, 6.0])
    lat = np.array([61.4, 5.3])
    cfg = CacheConfig(capacity_gb=10.0, miss_rate=0.1, hit_latency_ms=1.0)
    pts, w = served_latency_terms(rho, lat, np.array([True, False]), cfg)
    assert w.sum() == pytest.approx(rho.sum())      # no traffic lost
    np.testing.assert_allclose(pts, [61.4, 5.3, 1.0, 1.0])
    np.testing.assert_allclose(w, [0.4, 6.0, 3.6, 0.0])
    # no cache: identity
    pts, w = served_latency_terms(rho, lat, None, None)
    assert np.array_equal(pts, lat) and np.array_equal(w, rho)


def test_reactive_lru_semantics():
    c = ReactiveLRUCache(2.0)
    assert not c.access(0, 1.0)             # cold miss, admitted
    assert c.access(0, 1.0)                 # hit
    assert not c.access(1, 1.0)
    assert c.used_gb == 2.0
    assert not c.access(2, 1.0)             # evicts LRU (key 0)
    assert not c.contains(0)
    assert c.contains(1) and c.contains(2)
    assert c.mask(3).tolist() == [False, True, True]
    # an object larger than the whole cache is never admitted (and does
    # not wipe the cache trying)
    assert not c.access(9, 5.0)
    assert not c.contains(9) and c.used_gb == 2.0


# ---------------------------------------------------------------- replicas
def test_replicas_land_on_distinct_providers():
    t = big3_table()
    cfg = ScopeConfig(replicas=3, replica_rho_min=50.0, months=2.0)
    prob = _problem(np.random.default_rng(4), 14, cfg, t, rho_scale=40.0)
    plan = PlacementEngine(t, cfg).solve(prob)
    rp = PlacementEngine(t, cfg).plan_replicas(plan)
    assert rp.n_replicated > 0
    prov = np.asarray(t.provider_of_tier)
    prim = plan.assignment.tier.astype(int)
    for i in np.flatnonzero(rp.copies > 1):
        provs = [prov[prim[i]]]
        for j in range(rp.replica_tier.shape[1]):
            if rp.replica_tier[i, j] >= 0:
                provs.append(prov[rp.replica_tier[i, j]])
                # replicas store the primary's encoded payload
                assert rp.replica_scheme[i, j] == plan.assignment.scheme[i]
        assert len(provs) == len(set(provs)), f"copy collision for {i}"
        assert len(provs) == rp.copies[i]
    assert rp.replica_cents > 0.0
    assert 0.0 <= rp.read_rebate_cents
    # the fastest copy is never slower than the primary
    n = np.arange(prob.n)
    lat0 = (t.ttfb_seconds[prim]
            + prob.D[n, plan.assignment.scheme.astype(int)]) * 1e3
    assert (rp.best_latency_ms <= lat0 + 1e-9).all()
    pts, w = rp.latency_points(prob, plan.assignment)
    assert w.sum() == pytest.approx(prob.rho.sum())


def test_replicas_single_cloud_distinct_tiers_and_default_noop():
    t = azure_table()
    cfg = ScopeConfig(replicas=2, replica_rho_min=30.0)
    prob = _problem(np.random.default_rng(6), 10, cfg, t, rho_scale=40.0)
    eng = PlacementEngine(t, cfg)
    plan = eng.solve(prob)
    rp = eng.plan_replicas(plan)
    prim = plan.assignment.tier.astype(int)
    for i in np.flatnonzero(rp.copies > 1):
        assert rp.replica_tier[i, 0] != prim[i]
    # default config (replica_rho_min=inf) is a structural no-op
    cfg0 = ScopeConfig()
    prob0 = dataclasses.replace(prob, cfg=cfg0)
    rp0 = PlacementEngine(t, cfg0).plan_replicas(
        dataclasses.replace(plan, problem=prob0))
    assert (rp0.copies == 1).all()
    assert rp0.replica_cents == 0.0 and rp0.read_rebate_cents == 0.0
    assert rp0.n_replicated == 0


# ------------------------------------------------------- daemon integration
def test_steady_savings_includes_sla_relief():
    """A move to a faster cell gains exactly lambda * rho * excess-relief
    on top of the lambda=0 savings; inf-target rows gain nothing."""
    t = azure_table()
    cfg0 = ScopeConfig(schemes=("none",), use_compression=False,
                       sla_ms=30.0, sla_lambda=0.0)
    rng = np.random.default_rng(8)
    prob = _problem(rng, 12, cfg0, t, K=1)
    eng = PlacementEngine(t, cfg0)
    plan = eng.solve(prob)
    rho2 = prob.rho * np.where(np.arange(12) % 3 == 0, 60.0, 1.0)
    mig = eng.reoptimize(plan, rho2, months_held=2.0)
    assert mig.n_moved > 0
    sav0 = mig.steady_savings_cents()

    lam = 2.5
    cfg1 = dataclasses.replace(cfg0, sla_lambda=lam)
    prob1 = dataclasses.replace(mig.plan.problem, cfg=cfg1)
    mig1 = dataclasses.replace(
        mig, plan=dataclasses.replace(mig.plan, problem=prob1))
    sav1 = mig1.steady_savings_cents()

    n = np.arange(prob.n)
    old_l = np.maximum(mig.old_tier, 0)
    ex_old = np.maximum((t.ttfb_seconds[old_l]
                         + prob.D[n, np.maximum(mig.old_scheme, 0)]) * 1e3
                        - 30.0, 0.0)
    ex_new = np.maximum((t.ttfb_seconds[mig.new_tier]
                         + prob.D[n, mig.new_scheme]) * 1e3 - 30.0, 0.0)
    want = np.where(mig.candidate, lam * rho2 * (ex_old - ex_new), 0.0)
    np.testing.assert_allclose(sav1 - sav0, want, rtol=1e-9, atol=1e-9)

    # inf SLA: relief identically zero even with lambda > 0
    cfg_inf = dataclasses.replace(cfg0, sla_lambda=lam, sla_ms=np.inf)
    prob_inf = dataclasses.replace(mig.plan.problem, cfg=cfg_inf)
    mig_inf = dataclasses.replace(
        mig, plan=dataclasses.replace(mig.plan, problem=prob_inf))
    np.testing.assert_array_equal(mig_inf.steady_savings_cents(), sav0)


def test_daemon_reports_sla_penalty_not_in_spend():
    t = azure_table()
    cfg = ScopeConfig(schemes=("none",), use_compression=False,
                      sla_ms=30.0, sla_lambda=1.0)
    prob = _problem(np.random.default_rng(10), 10, cfg, t, K=1)
    eng = PlacementEngine(t, cfg)
    plan0 = eng.solve(prob)
    d = ReoptimizationDaemon(eng, plan=plan0)
    rho2 = prob.rho * np.where(np.arange(10) % 2 == 0, 40.0, 1.0)
    rep = d.step(rho2)
    assert rep.sla_penalty == d.plan.report.sla_penalty
    assert rep.sla_penalty >= 0.0
    # spend stays pure move cents: re-derive from the daemon's plan delta
    assert rep.spent_cents >= 0.0
    assert rep.steady_cents == d.plan.report.total_cents
