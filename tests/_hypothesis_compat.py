"""Fallback shim for the optional ``hypothesis`` test dependency.

Re-exports the real library when it is installed. Otherwise provides a
minimal deterministic stand-in covering exactly the subset this suite uses:

    @settings(max_examples=N, deadline=None)
    @given(st.integers(lo, hi))
    def test_x(seed): ...

The stand-in enumerates a fixed pseudo-random sample (endpoints included),
so property tests still run — just without shrinking or example databases.
"""

try:
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import random

    class _Integers:
        def __init__(self, min_value: int, max_value: int):
            self.min_value = int(min_value)
            self.max_value = int(max_value)

        def examples(self, n: int):
            rng = random.Random(0xC0FFEE ^ self.min_value ^ self.max_value)
            out = [self.min_value, self.max_value]
            while len(out) < n:
                out.append(rng.randint(self.min_value, self.max_value))
            return out[:n]

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> "_Integers":
            return _Integers(min_value, max_value)

    def given(strategy):
        def deco(fn):
            # Zero-arg wrapper: pytest must not see the sampled parameter
            # in the signature, or it would look for a fixture of that name.
            def wrapper():
                n = getattr(wrapper, "_max_examples", 20)
                for value in strategy.examples(n):
                    fn(value)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = 20
            return wrapper
        return deco

    def settings(max_examples: int = 20, deadline=None, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
