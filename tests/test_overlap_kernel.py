"""Overlap-kernel property suite: the fractional-overlap matrix backends
(numpy / jnp-ref / pallas-interpret) and the PartitionIndex CSR core.

Each property runs over seeded random instances via the hypothesis shim
(`_hypothesis_compat`): symmetry, [0, 1] range, exact zero for disjoint
code sets (the PYTHONHASHSEED bug class from PR 2 — no fp residue may link
disjoint partitions), permutation invariance, cross-backend differentials
to 1e-5, and lossless ``Partition`` <-> ``PartitionIndex`` round-trip.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import datapart as dp
from repro.kernels import ops


def _instance(seed, n_parts=18, n_files=40, unit=False):
    rng = np.random.default_rng(seed)
    files = [f"t/{i}" for i in range(n_files)]
    sizes = {f: 1.0 if unit else float(rng.random() * 4 + 0.25)
             for f in files}
    qf = []
    for _ in range(n_parts):
        k = int(rng.integers(1, 7))
        fs = tuple(rng.choice(files, size=k, replace=False))
        qf.append((fs, float(rng.random() * 9 + 0.5)))
    return dp.make_partitions(qf, sizes)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_symmetry_and_range(seed):
    idx = dp.PartitionIndex.from_partitions(_instance(seed))
    w = idx.overlap_matrix("numpy")
    assert np.allclose(w, w.T, atol=0)
    assert (w >= 0.0).all() and (w <= 1.0 + 1e-6).all()
    # self-overlap is exactly 1
    assert np.allclose(np.diag(w), 1.0, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_disjoint_pairs_exact_zero(seed):
    """Partitions over disjoint file blocks: every cross weight must be
    exactly 0.0 in every backend — no summation-order residue."""
    rng = np.random.default_rng(seed)
    sizes = {f"t/{i}": float(rng.random() * 3 + 0.1) for i in range(60)}
    fs = dp.FileSizes(sizes)
    parts = [dp.Partition(frozenset(f"t/{j}" for j in range(10 * i, 10 * i + 10)),
                          1.0 + i, fs) for i in range(6)]
    idx = dp.PartitionIndex.from_partitions(parts)
    for backend in ("numpy", "ref", "interpret"):
        w = np.asarray(idx.overlap_matrix(backend))
        off = w[~np.eye(len(parts), dtype=bool)]
        assert (off == 0.0).all(), backend
    pi, pj = idx.candidate_pairs()
    assert len(pi) == 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_permutation_invariance(seed):
    parts = _instance(seed)
    perm = np.random.default_rng(seed + 1).permutation(len(parts))
    idx = dp.PartitionIndex.from_partitions(parts)
    idx_p = dp.PartitionIndex.from_partitions([parts[p] for p in perm])
    w = idx.overlap_matrix("numpy")
    w_p = idx_p.overlap_matrix("numpy")
    assert np.allclose(w[np.ix_(perm, perm)], w_p, atol=1e-9)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_backend_differential(seed):
    """numpy / vmapped-jnp / pallas-interpret agree to 1e-5 (f32 kernels
    vs f64 host sweep)."""
    idx = dp.PartitionIndex.from_partitions(_instance(seed))
    w_np = idx.overlap_matrix("numpy")
    w_ref = np.asarray(idx.overlap_matrix("ref"))
    w_int = np.asarray(idx.overlap_matrix("interpret"))
    assert np.abs(w_np - w_ref).max() < 1e-5
    assert np.abs(w_np - w_int).max() < 1e-5


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_csr_round_trip_identity(seed):
    parts = _instance(seed)
    idx = dp.PartitionIndex.from_partitions(parts)
    back = idx.to_partitions()
    assert [(p.files, p.rho) for p in back] == \
           [(p.files, p.rho) for p in parts]
    # same FileSizes object -> memoized spans, read_cost bit-identical
    assert back[0].sizes is parts[0].sizes
    assert idx.read_cost() == pytest.approx(dp.read_cost(parts), abs=1e-9)
    for i in range(idx.n):
        row = idx.row(i)
        assert (np.diff(row) > 0).all()  # ascending, duplicate-free


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_candidate_pairs_exact(seed):
    """Unsampled candidate set == {(i, j) : overlap > 0, i < j}."""
    idx = dp.PartitionIndex.from_partitions(_instance(seed))
    w = idx.overlap_matrix("numpy")
    pi, pj = idx.candidate_pairs()
    got = set(zip(pi.tolist(), pj.tolist()))
    want = {(i, j) for i in range(idx.n) for j in range(i + 1, idx.n)
            if w[i, j] > 0.0}
    assert got == want


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_sampled_candidates_subset(seed):
    idx = dp.PartitionIndex.from_partitions(_instance(seed, n_parts=25))
    pi, pj = idx.candidate_pairs()
    exact = set(zip(pi.tolist(), pj.tolist()))
    si, sj = idx.candidate_pairs(sample=0.5, seed=seed)
    assert set(zip(si.tolist(), sj.tolist())) <= exact
    ci, cj = idx.candidate_pairs(max_degree=2)
    assert set(zip(ci.tolist(), cj.tolist())) <= exact


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_pair_overlap_spans_match_setwise(seed):
    parts = _instance(seed)
    idx = dp.PartitionIndex.from_partitions(parts)
    n = idx.n
    pi, pj = np.triu_indices(n, 1)
    inter = idx.pair_overlap_spans(pi, pj)
    for t in range(0, len(pi), 7):
        i, j = int(pi[t]), int(pj[t])
        assert inter[t] == pytest.approx(dp.overlap(parts[i], parts[j]),
                                         abs=1e-9)


def test_rectangular_block_matches_square():
    """The codes_b operand (the sharded row-block path) must reproduce the
    corresponding rows of the square sweep."""
    idx = dp.PartitionIndex.from_partitions(_instance(123, n_parts=12))
    codes, sizes, spans = idx.padded_codes()
    full = np.asarray(ops.fractional_overlap_matrix(codes, sizes, spans,
                                                    impl="ref"))
    blk = np.asarray(ops.fractional_overlap_matrix(
        codes[:5], sizes, spans[:5], codes_b=codes, spans_b=spans,
        impl="ref"))
    assert np.abs(full[:5] - blk).max() < 1e-6
    blk_i = np.asarray(ops.fractional_overlap_matrix(
        codes[:5], sizes, spans[:5], codes_b=codes, spans_b=spans,
        impl="interpret"))
    assert np.abs(full[:5] - blk_i).max() < 1e-5


def test_ops_dispatch_aliases():
    """'jnp' (the engine backend name) must resolve to the jnp oracle."""
    idx = dp.PartitionIndex.from_partitions(_instance(5, n_parts=6))
    codes, sizes, spans = idx.padded_codes()
    a = np.asarray(ops.fractional_overlap_matrix(codes, sizes, spans,
                                                 impl="jnp"))
    b = np.asarray(ops.fractional_overlap_matrix(codes, sizes, spans,
                                                 impl="ref"))
    assert np.array_equal(a, b)
