"""SCOPe quickstart: optimize tier + compression for a synthetic data lake.

Runs the full paper pipeline on generated TPC-H-style data in ~a minute:
  query log -> initial partitions (query families) -> G-PART merge ->
  compression measurement/prediction -> OPTASSIGN -> cost report.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.costs import azure_table
from repro.core.scope import ScopeConfig, run_pipeline
from repro.data import tpch


def main():
    print("generating TPC-H-like data + 20 queries/template ...")
    db = tpch.generate(scale_rows=6000, seed=0)
    queries = tpch.generate_queries(db, n_per_template=5, seed=1)
    parts, file_rows = tpch.partitions_from_queries(db, queries)
    table = azure_table()

    default = run_pipeline(parts, file_rows, table, ScopeConfig(
        use_partitioning=False, use_tiering=False, use_compression=False,
        fixed_tier=0, tier_whitelist=(0, 1, 2)))
    scope = run_pipeline(parts, file_rows, table, ScopeConfig(
        tier_whitelist=(0, 1, 2)))

    def row(name, r):
        print(f"{name:38s} storage={r.storage_cents:9.4f}c "
              f"read={r.read_cents:9.4f}c decomp={r.decomp_cents:8.5f}c "
              f"total={r.total_cents:9.4f}c ttfb={r.read_latency_ttfb:.4f}s "
              f"tiers={r.tiering_scheme}")

    print(f"\n{'policy':38s} costs over 5.5 months "
          f"({default.n_partitions} -> {scope.n_partitions} partitions)")
    row("Default (store on premium)", default)
    row("SCOPe (total cost focused)", scope)
    saving = 100 * (1 - scope.total_cents / default.total_cents)
    print(f"\nSCOPe saves {saving:.1f}% vs the platform default "
          f"(paper TPC-H band, Tables IX-XI: 82-92%)")


if __name__ == "__main__":
    main()
