"""Continuous re-optimization with budget-capped migrations.

An enterprise drift trace streams month by month through a
``ReoptimizationDaemon`` wrapping a ``StreamingEngine``, twice: once with
an unbounded budget (every proposed move executes immediately) and once
with a per-cycle cents cap (the savings-per-cent knapsack picks which
moves run now; the rest are deferred with priority aging). The capped run
spends smoothly — never more than the cap per cycle — yet its cumulative
cost lands within a few percent of the unbudgeted trajectory.

Run:  PYTHONPATH=src python examples/daemon_budget.py
"""

import numpy as np

from repro.core.costs import azure_table
from repro.core.daemon import MigrationBudget, ReoptimizationDaemon
from repro.core.engine import ScopeConfig, StreamingEngine
from repro.data import workloads as wl


def run_trace(budget: MigrationBudget):
    w = wl.generate_workload(n_datasets=120, n_months=10, seed=11)
    rng = np.random.default_rng(11)
    cfg = ScopeConfig(use_compression=False, months=1.0)
    eng = StreamingEngine(azure_table(), cfg, wl.dataset_file_sizes(w),
                          drift_threshold=0.5, rho_abs_tol=1.0)
    daemon = ReoptimizationDaemon(eng, budget=budget)
    for batch in wl.stream_query_log(w, rng):
        if batch:
            daemon.step(batch, months=1.0)
    return daemon


def main():
    unb = run_trace(MigrationBudget())
    peak = max(r.spent_cents for r in unb.history)
    cap = 0.4 * peak
    capped = run_trace(MigrationBudget(cents_per_cycle=cap))

    print(f"unbudgeted peak cycle spend: {peak:9.2f} c   "
          f"cap: {cap:9.2f} c/cycle\n")
    print("cycle |      unbudgeted spend |  capped spend  deferred  age")
    for u, c in zip(unb.history, capped.history):
        print(f"{u.cycle:5d} | {u.spent_cents:21.2f} | {c.spent_cents:13.2f}"
              f"  {c.n_deferred:8d}  {c.max_deferral_age:3d}")
        assert c.spent_cents <= cap + 1e-9

    cum_u = sum(r.steady_cents + r.spent_cents for r in unb.history)
    cum_c = sum(r.steady_cents + r.spent_cents for r in capped.history)
    print(f"\ncumulative cost  unbudgeted: {cum_u:12.2f} c")
    print(f"cumulative cost  capped:     {cum_c:12.2f} c   "
          f"(+{100 * (cum_c / cum_u - 1):.2f}%)")
    print(f"moves executed   unbudgeted: "
          f"{sum(r.n_selected for r in unb.history)}   capped: "
          f"{sum(r.n_selected for r in capped.history)}")


if __name__ == "__main__":
    main()
