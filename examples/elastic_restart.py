"""Fault-tolerance / elasticity demo: train, 'crash', restore on a
DIFFERENT simulated topology (elastic restart), keep training.

Runs two phases in subprocesses with different host-device counts to prove
the checkpoint is topology-independent:
  phase 1: 4 hosts, train N steps, checkpoint (SCOPe-tiered store on /tmp)
  phase 2: 2 hosts, restore latest, verify loss continuity, train more.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import pathlib
import pickle
import subprocess
import sys
import textwrap

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
STORE = "/tmp/elastic_demo_store.pkl"

PHASE = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'
import pickle, jax, jax.numpy as jnp
from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.launch.mesh import make_test_mesh
from repro.storage.store import TieredStore
from repro.training import train_step as ts

cfg = get_config('qwen3-4b', smoke=True)
tcfg = ts.TrainConfig(remat=False)
store = TieredStore()
try:
    store._objs = pickle.load(open('{store}', 'rb'))
except FileNotFoundError:
    pass
mgr = CheckpointManager(store, keep=4)
state = ts.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
start = 0
if mgr.latest_step() is not None:
    state, start = mgr.restore(state)
    print('restored at step', start, 'on', {devices}, 'devices')
step_fn = ts.make_train_step(cfg, tcfg)
tok = jax.random.randint(jax.random.PRNGKey(7), (8, 33), 0, cfg.vocab_size)
batch = dict(tokens=tok[:, :-1], labels=tok[:, 1:])
for i in range(start, start + {steps}):
    state, m = step_fn(state, batch)
print('phase done: step', start + {steps}, 'loss', float(m['loss']))
mgr.save(start + {steps}, state, blocking=True)
pickle.dump(store._objs, open('{store}', 'wb'))
"""


def run_phase(devices: int, steps: int) -> str:
    code = PHASE.format(devices=devices, steps=steps, store=STORE)
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=900,
                         env={"PYTHONPATH": SRC, "HOME": "/root",
                              "PATH": os.environ.get("PATH", "/usr/bin")})
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    return res.stdout


def main():
    if os.path.exists(STORE):
        os.remove(STORE)
    print("phase 1: 4 hosts")
    print(run_phase(4, 8))
    print("phase 2 (elastic restart on 2 hosts):")
    out = run_phase(2, 8)
    print(out)
    assert "restored at step 8" in out
    print("elastic restart OK: checkpoint is topology-independent")


if __name__ == "__main__":
    main()
