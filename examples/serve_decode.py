"""Serving example: prefill + batched greedy decode with a KV cache
(yi-9b smoke-size on CPU; identical code path lowers on the production
mesh via launch/dryrun decode cells).

    PYTHONPATH=src python examples/serve_decode.py --tokens 24 --batch 4
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import transformer as tr
from repro.serving.decode import make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    B = args.batch
    max_seq = args.prompt_len + args.tokens + 1
    cache = tr.init_cache(cfg, B, max_seq=max_seq)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (B, args.prompt_len), 0, cfg.vocab_size)
    step = make_decode_step(cfg)

    # prefill by stepping the prompt (cache-writing prefill fuses this on TPU)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, i:i + 1],
                             jnp.full((B,), i, jnp.int32))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [tok]
    for j in range(args.tokens - 1):
        logits, cache = step(params, cache, tok,
                             jnp.full((B,), args.prompt_len + j, jnp.int32))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    total = B * args.tokens
    print(f"arch={cfg.name} (smoke) batch={B}")
    print(f"generated {args.tokens} tokens/seq in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU)")
    print("sample token ids:", gen[0, :16].tolist())
    assert bool(jnp.isfinite(logits).all())


if __name__ == "__main__":
    main()
