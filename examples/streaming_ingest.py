"""Streaming ingestion demo: a year of enterprise access logs, one month at
a time, through StreamingEngine + a metered TieredStore.

Each month the engine folds the new query families into the standing G-PART
partitioning (compacting when drift crosses the threshold), re-optimizes
placement with migration costs internalized, and ``sync_plan`` reconciles a
live tiered store: new partitions are written, drifted ones migrate, and
partitions merged away or expired from the rolling window are deleted.

    PYTHONPATH=src python examples/streaming_ingest.py
"""

import numpy as np

from repro.core.costs import TIER_NAMES, azure_table
from repro.core.engine import ScopeConfig, StreamingEngine
from repro.data import workloads as wl
from repro.storage.store import TieredStore


def main() -> None:
    w = wl.generate_workload(n_datasets=120, n_months=12, seed=11)
    rng = np.random.default_rng(11)
    sizes = wl.dataset_file_sizes(w)
    table = azure_table()
    eng = StreamingEngine(table, ScopeConfig(use_compression=False,
                                             months=1.0),
                          sizes, window=6, drift_threshold=0.5)
    store = TieredStore(table)

    print(f"{'month':>5} {'parts':>5} {'new':>4} {'moved':>5} {'cmpct':>5} "
          f"{'migrate_c':>10} {'steady_c':>10}  store ops")
    for month, batch in enumerate(wl.stream_query_log(w, rng)):
        if not batch:
            continue
        mig = eng.ingest_and_reoptimize(batch, months=1.0)
        parts = mig.plan.problem.partitions
        # demo payloads: 1 byte per MB of span keeps the simulation light
        payloads = [b"\0" * max(int(p.span * 1e3), 1) for p in parts]
        ops = store.sync_plan(mig.plan, payloads=payloads)
        store.advance_months(1.0)
        r = eng.history[-1]
        print(f"{month:>5} {r.n_partitions:>5} {r.n_new:>4} {r.n_moved:>5} "
              f"{str(r.compacted):>5} {r.migration_cents:>10.2f} "
              f"{r.steady_cents:>10.1f}  {ops}")

    usage = store.tier_usage_gb()
    print("\nfinal tier usage (simulated GB):")
    for t, name in enumerate(TIER_NAMES):
        print(f"  {name:>8}: {usage[t]:.6f}")
    print("\nbilling meter:")
    for k, v in store.meter.as_dict().items():
        print(f"  {k:>15}: {float(v):.4f}")


if __name__ == "__main__":
    main()
