"""End-to-end driver: train an LM with the full substrate —
tiered data loader, AdamW train step, SCOPe-managed checkpoints
(tier+codec per shard, async write, lifecycle migration), crash-restart.

Default is a CPU-friendly ~20M-param qwen3-family model; pass --big for the
~100M documented configuration (same code path, longer wall time).

    PYTHONPATH=src python examples/train_lm_tiered_ckpt.py --steps 100
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data.loader import TieredDataLoader, write_token_shards
from repro.models.config import Stage
from repro.storage.store import TieredStore
from repro.training import train_step as ts


def model_config(big: bool):
    cfg = get_config("qwen3-4b", smoke=True)
    if big:   # ~100M params
        return cfg.scaled(d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
                          d_ff=1536, vocab_size=32768,
                          stages=(Stage(("attn",), 8),))
    # ~20M params
    return cfg.scaled(d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
                      d_ff=768, vocab_size=8192, stages=(Stage(("attn",), 4),))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = model_config(args.big)
    tcfg = ts.TrainConfig(remat=False, microbatches=1)
    store = TieredStore()
    mgr = CheckpointManager(store, keep=4)

    print("writing tokenized shards into the tiered store ...")
    shards = write_token_shards(store, n_shards=24, rows=64, seq=args.seq,
                                vocab=cfg.vocab_size, tier=1)
    loader = TieredDataLoader(store, shards, batch=args.batch, seq=args.seq)

    state = ts.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        state, start_step = mgr.restore(state)
        print(f"resumed from checkpoint step {start_step}")
    step_fn = ts.make_train_step(cfg, tcfg)

    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(state["params"]))
    print(f"model: {n_params/1e6:.1f}M params | steps={args.steps}")

    t0 = time.time()
    i = start_step
    losses = []
    while i < args.steps:
        for batch in loader.batches(epoch=i // max(len(shards), 1)):
            if i >= args.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
            i += 1
            if i % 10 == 0:
                rate = i - start_step
                print(f"step {i:4d} loss={losses[-1]:.4f} "
                      f"({(time.time()-t0)/max(rate,1):.2f}s/step)")
            if i % args.ckpt_every == 0:
                mgr.save(i, state)          # async, SCOPe-tiered
    mgr.wait()
    print(f"\nfinal loss {losses[-1]:.4f} (from {losses[0]:.4f})")
    print("checkpoint storage bill:", {
        k: round(v, 6) for k, v in store.meter.as_dict().items()
        if isinstance(v, float) and v})
    print("tier usage (GB):", {k: round(v, 6)
                               for k, v in store.tier_usage_gb().items() if v})


if __name__ == "__main__":
    main()
