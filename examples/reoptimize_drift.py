"""Online re-optimization under access drift, applied to a live TieredStore.

Demonstrates the staged PlacementEngine end to end:

  1. optimize placement for a TPC-H-style workload and materialize it into a
     metered TieredStore (``apply_plan``);
  2. let a month pass, then drift the access pattern (some partitions go
     hot, some go cold);
  3. ``reoptimize`` computes an incremental MigrationPlan — tier-change
     transfer costs and early-deletion penalties are part of the objective,
     and undrifted partitions keep their compression scheme;
  4. ``migrate`` applies it; the BillingMeter shows exactly what the move
     cost and what the new steady state saves.

    PYTHONPATH=src python examples/reoptimize_drift.py
"""

import numpy as np

from repro.core.costs import azure_table
from repro.core.engine import PlacementEngine, ScopeConfig
from repro.data import tpch
from repro.storage.store import TieredStore


def main():
    print("generating TPC-H-like data + queries ...")
    db = tpch.generate(scale_rows=4000, seed=0)
    queries = tpch.generate_queries(db, n_per_template=4, seed=1)
    parts, file_rows = tpch.partitions_from_queries(db, queries)
    table = azure_table()

    eng = PlacementEngine(table, ScopeConfig(tier_whitelist=(0, 1, 2),
                                             months=1.0))
    plan = eng.run(parts, file_rows)
    print(f"\ninitial placement: {plan.problem.n} partitions, "
          f"tiers={plan.report.tiering_scheme}, "
          f"projected {plan.report.total_cents:.4f}c/month")

    store = TieredStore(table)
    keys = store.apply_plan(plan)
    store.advance_months(1.0)

    # drift: the 2 coldest partitions become the hottest and vice versa
    rho = plan.problem.rho
    new_rho = rho.copy()
    order = np.argsort(rho)
    new_rho[order[:2]] = rho.max() * 10.0
    new_rho[order[-2:]] = max(rho.min() / 10.0, 1e-3)

    mig = eng.reoptimize(plan, new_rho, months_held=1.0)
    stale_cents = eng.billing(mig.plan.problem, plan.assignment).total_cents
    print(f"\ndrift: {mig.n_moved}/{plan.problem.n} partitions migrate")
    print(f"  one-off: transfer={mig.migration_cents:.6f}c "
          f"early-delete={mig.penalty_cents:.6f}c")
    print(f"  steady state: stale={stale_cents:.4f}c/month -> "
          f"re-optimized={mig.plan.report.total_cents:.4f}c/month")

    before = store.meter.total_cents
    store.migrate(mig, keys)
    print(f"\nBillingMeter after migrate (+{store.meter.total_cents - before:.6f}c):")
    for field, val in store.meter.as_dict().items():
        if isinstance(val, float):
            print(f"  {field:16s} {val:.6f}")


if __name__ == "__main__":
    main()
