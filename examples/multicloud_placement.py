"""Multi-cloud placement across AWS S3 + GCP GCS + Azure Blob.

Demonstrates the flattened ``(provider, tier)`` placement space end to end:

  1. build the 12-tier AWS+GCP+Azure table (``costs.big3_table``) — the
     cross-provider egress matrix becomes the off-diagonal blocks of
     ``tier_change_cents_gb``;
  2. optimize a synthetic enterprise workload across all three providers
     and compare against the best single-provider plan
     (``ScopeConfig.provider_whitelist``);
  3. drift the access pattern and ``reoptimize`` — provider switches pay
     the source provider's egress exactly once, composed with early-delete
     penalties, and the optimizer only crosses when the steady-state saving
     beats that wall;
  4. mirror the migration into a metered TieredStore: the meter's new
     ``egress_cents`` line matches the plan's ``egress_cents``.

    PYTHONPATH=src python examples/multicloud_placement.py
"""

import dataclasses

import numpy as np

from repro.core.costs import big3_table
from repro.core.engine import PlacementEngine, PlacementProblem, ScopeConfig
from repro.storage.store import TieredStore

SCHEMES = ("none",)


def synthetic_problem(table, cfg, n=120, seed=7):
    rng = np.random.default_rng(seed)
    # tiny spans so real payloads can back the store; placement economics
    # are scale-invariant per partition
    spans = rng.lognormal(0.0, 1.3, n) * 2e-5
    rho = rng.gamma(0.6, 30.0, n)
    R = np.ones((n, 1))
    D = np.zeros((n, 1))
    raws = [b"\xa5" * max(int(s * 1e9), 1) for s in spans]
    return PlacementProblem(spans_gb=spans, rho=rho,
                            current_tier=np.full(n, -1), R=R, D=D,
                            schemes=SCHEMES, table=table, cfg=cfg,
                            raw_bytes=raws)


def main():
    table = big3_table()
    print(f"flattened space: {table.num_tiers} tiers across "
          f"{table.provider_names}")
    cfg = ScopeConfig(schemes=SCHEMES, months=6.0)
    eng = PlacementEngine(table, cfg)
    problem = synthetic_problem(table, cfg)
    plan = eng.solve(problem)
    print(f"\ncross-provider plan: {plan.report.total_cents:.6f}c, "
          f"partitions per provider {plan.report.provider_scheme}")

    for p in table.provider_names:
        c1 = ScopeConfig(schemes=SCHEMES, months=6.0,
                         provider_whitelist=(p,))
        single = PlacementEngine(table, c1).solve(
            synthetic_problem(table, c1)).report.total_cents
        print(f"  {p:>5}-only plan:     {single:.6f}c")

    store = TieredStore(table)
    keys = store.apply_plan(plan)
    store.advance_months(0.5)

    rng = np.random.default_rng(11)
    new_rho = problem.rho.copy()
    flip = rng.random(problem.n) < 0.2
    new_rho[flip] *= rng.choice([1e-3, 200.0], int(flip.sum()))
    mig = eng.reoptimize(plan, new_rho, months_held=0.5)
    crossed = int(((table.provider_of_tier[mig.new_tier]
                    != table.provider_of_tier[mig.old_tier])
                   & mig.moved).sum())
    print(f"\ndrift at list-price egress: {mig.n_moved} moves, "
          f"{crossed} across providers (egress lock-in)")
    print(f"  migration {mig.migration_cents:.8f}c "
          f"(egress {mig.egress_cents:.8f}c) "
          f"+ early-delete {mig.penalty_cents:.8f}c")
    store.migrate(mig, keys)

    # Same drift under a negotiated interconnect (0.5 c/GB both ways):
    # provider switches become economical, and the store's egress meter
    # matches the plan's egress line exactly.
    interconnect = np.full((3, 3), 0.5)
    np.fill_diagonal(interconnect, 0.0)
    disc = dataclasses.replace(table, egress_cents_gb=interconnect)
    eng_d = PlacementEngine(disc, cfg)
    plan_d = eng_d.solve(synthetic_problem(disc, cfg))
    store_d = TieredStore(disc)
    keys_d = store_d.apply_plan(plan_d)
    store_d.advance_months(0.5)
    mig_d = eng_d.reoptimize(plan_d, new_rho, months_held=0.5)
    crossed_d = int(((disc.provider_of_tier[mig_d.new_tier]
                      != disc.provider_of_tier[mig_d.old_tier])
                     & mig_d.moved).sum())
    print(f"\nsame drift at 0.5c/GB interconnect: {mig_d.n_moved} moves, "
          f"{crossed_d} across providers")
    print(f"  migration {mig_d.migration_cents:.8f}c "
          f"(egress {mig_d.egress_cents:.8f}c) "
          f"+ early-delete {mig_d.penalty_cents:.8f}c")
    e0 = store_d.meter.egress_cents
    store_d.migrate(mig_d, keys_d)
    print(f"  store egress metered: {store_d.meter.egress_cents - e0:.8f}c "
          f"(plan said {mig_d.egress_cents:.8f}c)")
    print(f"  store bill so far: {store_d.meter.total_cents:.6f}c")


if __name__ == "__main__":
    main()
